#!/usr/bin/env python3
"""Structural invariant lint for the Rust crate (`rust/src/**/*.rs`).

The crate's concurrency and unsafe-code story rests on a handful of
*structural* invariants that the compiler cannot enforce and that code
review has to re-check on every PR. This lint makes them mechanical.
Four rules, each scanned over non-test code only (everything from the
first top-level `#[cfg(test)]` / `#[cfg(all(test, ...))]` line to end of
file is skipped — test modules sit last by crate convention):

  thread-spawn  `thread::spawn` / `thread::scope` / `thread::Builder`
                may appear only in util/pool.rs and util/sync.rs (the
                pool and its std/loom seam). Every other module must
                parallelize through the pool so that the loom/TSan/Miri
                lanes, which model and instrument the pool, cover all
                threading in the crate. Allowlisted: coordinator/server.rs
                (the one accept-loop thread predating the rule; its spawn
                is documented at the site).

  env-var       `env::var` may appear only in util/env.rs, the central
                `TBGEMM_*` registry. Scattered reads defeat the
                read-once OnceLock caching and make the knob surface
                undiscoverable.

  safety        Every `unsafe {` block must carry a `// SAFETY:` comment
                in the contiguous comment/attribute block above it. (The companion
                compiler-side half of this rule is
                `#![deny(unsafe_op_in_unsafe_fn)]` +
                `#![deny(clippy::undocumented_unsafe_blocks)]` in
                src/lib.rs; this textual check also covers cfg'd-out
                ISA arms that the host clippy pass never expands.)

  unwrap        `.unwrap()` / `.expect(` are banned in non-test library
                code, except (a) lock/wait/join poisoning — propagating
                a poisoned mutex is strictly worse than the panic that
                poisoned it, and (b) an explicit per-file allowlist
                below, each entry with its justification.

Output: `path:line: [rule] message` per violation, exit 1 if any.
Run `--self-test` to verify the lint still catches a seeded violation of
every rule (CI runs the self-test before the real scan, so a regression
in this script cannot silently disable a rule).
"""

import re
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "rust" / "src"

# ---- rule tables -------------------------------------------------------

# thread-spawn: files allowed to touch std/loom threads at all.
THREAD_ALLOWED = {
    "util/pool.rs",  # the worker pool itself
    "util/sync.rs",  # the std/loom spawn seam the pool goes through
}
# Pre-existing, documented exceptions (reviewed spawns outside the pool).
THREAD_ALLOWLIST = {
    "coordinator/server.rs": "dedicated accept-loop thread; serves the listener, documented at the site",
}

# env-var: the central registry.
ENV_ALLOWED = {"util/env.rs"}

# unwrap: per-file allowlist with justifications, printed on violation
# elsewhere so the error message teaches the policy.
UNWRAP_ALLOWLIST = {
    "bench/grid.rs": "bench-only table formatting; panicking on a malformed row is the desired behavior",
    "bench/ratio.rs": "bench-only harness; measurement cannot proceed past a malformed configuration",
    "coordinator/server.rs": "listener setup; the server cannot start without a bound socket",
    "main.rs": "CLI entry point; argument/IO failures should abort with a message",
    "nn/twin.rs": "construction-time shape invariant established by the same function",
    "util/pool.rs": "single-task fast path pops the task it just pushed",
    "util/timer.rs": "monotonic clock arithmetic on durations the same fn produced",
    "util/sync.rs": "thread spawn failure is unrecoverable at pool construction",
}
# Lines where unwrap/expect handles lock poisoning or thread join — the
# crate-wide convention (see util/pool.rs module docs) is to propagate
# the originating panic, not to stack a second error path on top.
UNWRAP_LINE_EXEMPT = re.compile(r"\.lock\(\)|\.wait\(|wait_timeout|\.join\(")

TEST_GATE = re.compile(r"^\s*#\[cfg\((all\()?test\b")
RE_THREAD = re.compile(r"\bthread::(spawn|scope|Builder)\b")
RE_ENV = re.compile(r"\benv::var\b")
RE_UNSAFE_BLOCK = re.compile(r"\bunsafe\s*\{")
RE_UNSAFE_ALLOW = re.compile(r"#\[allow\(clippy::undocumented_unsafe_blocks\)\]")
RE_UNWRAP = re.compile(r"\.unwrap\(\)|\.expect\(")
# A SAFETY justification must sit in the contiguous comment/attribute
# block directly above the unsafe block (or on the block's own line);
# `MAX_LOOKBACK` only bounds the upward walk against pathological files.
MAX_LOOKBACK = 40
COMMENT_OR_ATTR = re.compile(r"^\s*(//|#\[|#!\[|$)")


def strip_comment(line: str) -> str:
    """Drop a trailing // comment (good enough: the crate has no string
    literals containing `//` on lines these rules match)."""
    idx = line.find("//")
    return line if idx < 0 else line[:idx]


def non_test_lines(text: str):
    """Yield (1-based line number, raw line) up to the first top-level
    test gate; test modules sit at the bottom of every file."""
    for i, line in enumerate(text.split("\n"), start=1):
        if TEST_GATE.match(line):
            return
        yield i, line


def lint_file(path: Path, rel: str):
    text = path.read_text()
    lines = list(non_test_lines(text))
    violations = []

    for i, raw in lines:
        line = strip_comment(raw)

        if RE_THREAD.search(line) and rel not in THREAD_ALLOWED:
            if rel in THREAD_ALLOWLIST:
                pass  # reviewed exception
            else:
                violations.append(
                    (i, "thread-spawn", "direct thread creation outside util/pool.rs — parallelize through the pool "
                                        "so the loom/TSan/Miri lanes cover it")
                )

        if RE_ENV.search(line) and rel not in ENV_ALLOWED:
            violations.append(
                (i, "env-var", "environment read outside util/env.rs — add a cached accessor to the central registry")
            )

        if RE_UNWRAP.search(line) and rel not in UNWRAP_ALLOWLIST and not UNWRAP_LINE_EXEMPT.search(line):
            hints = "; ".join(f"{k}: {v}" for k, v in sorted(UNWRAP_ALLOWLIST.items()))
            violations.append(
                (i, "unwrap", "unwrap/expect in non-test library code — return an error, or handle lock poisoning via "
                              f"the lock()/wait()/join() exemption (allowlisted files: {hints})")
            )

    # safety: every unsafe block needs a SAFETY comment either on its
    # own line or in the contiguous comment/attribute block above it.
    # Scanned over raw lines because the justification *is* a comment.
    raw_by_no = dict(lines)
    for i, raw in lines:
        code = strip_comment(raw)
        if not RE_UNSAFE_BLOCK.search(code):
            continue
        window = [raw]
        j = i - 1
        while j >= 1 and i - j <= MAX_LOOKBACK:
            above = raw_by_no.get(j, "")
            if not COMMENT_OR_ATTR.match(above):
                break
            window.append(above)
            j -= 1
        if not any("SAFETY:" in w for w in window):
            violations.append(
                (i, "safety", "unsafe block without a `// SAFETY:` comment in the contiguous "
                              "comment block above it")
            )

    return violations


def run_scan(src: Path):
    count = 0
    for path in sorted(src.rglob("*.rs")):
        rel = path.relative_to(src).as_posix()
        for line_no, rule, msg in lint_file(path, rel):
            print(f"{path.relative_to(REPO)}:{line_no}: [{rule}] {msg}")
            count += 1
    return count


# ---- self-test ---------------------------------------------------------

SEEDED = {
    "thread-spawn": 'fn bad() { std::thread::spawn(|| {}); }\n',
    "env-var": 'fn bad() -> bool { std::env::var("TBGEMM_X").is_ok() }\n',
    "safety": 'fn bad(p: *const u8) -> u8 { unsafe { *p } }\n',
    "unwrap": 'fn bad(s: &str) -> i32 { s.parse().unwrap() }\n',
}

CLEAN = """\
//! Self-test fixture: every rule satisfied.
fn spawn_free() {}
// SAFETY: reads a valid reference reborrowed as a raw pointer.
fn fine(x: &u8) -> u8 {
    let p = x as *const u8;
    // SAFETY: `p` was just created from a live shared reference.
    unsafe { *p }
}
fn poisoning(m: &std::sync::Mutex<i32>) -> i32 {
    *m.lock().unwrap()
}
#[cfg(test)]
mod tests {
    fn tests_may_unwrap(s: &str) -> i32 {
        s.parse().unwrap()
    }
}
"""


def self_test() -> int:
    failures = []
    with tempfile.TemporaryDirectory() as td:
        tree = Path(td)
        # Each seeded violation must be caught...
        for rule, code in SEEDED.items():
            f = tree / f"seed_{rule.replace('-', '_')}.rs"
            f.write_text(code)
            got = lint_file(f, f.name)
            if not any(r == rule for _, r, _ in got):
                failures.append(f"rule `{rule}` missed its seeded violation")
            f.unlink()
        # ...and the clean fixture must pass every rule.
        f = tree / "clean.rs"
        f.write_text(CLEAN)
        got = lint_file(f, f.name)
        if got:
            failures.append(f"clean fixture flagged: {got}")

    if failures:
        for msg in failures:
            print(f"self-test FAILED: {msg}", file=sys.stderr)
        return 1
    print(f"self-test OK: {len(SEEDED)} seeded violations caught, clean fixture passes")
    return 0


def main() -> int:
    if "--self-test" in sys.argv[1:]:
        return self_test()
    count = run_scan(SRC)
    if count:
        print(f"\nstructural lint: {count} violation(s)", file=sys.stderr)
        return 1
    print("structural lint: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
