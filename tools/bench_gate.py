#!/usr/bin/env python3
"""Compare a fresh gemm_micro run against the committed baseline.

`cargo bench --bench gemm_micro` (run from `rust/`) writes
`rust/BENCH_gemm.json`: a JSON array of records
`{kind, variant, m, n, k, ns_per_iter, gops}`. This gate compares that
fresh run against the committed `rust/BENCH_gemm.baseline.json` keyed by
`(kind, variant, m, n, k)` and fails (exit 1) when any record regresses
by more than `--tolerance` (default 1.6x slower, i.e. fresh gops <
baseline gops / 1.6 — generous, because CI machines are noisy and
shared; the gate exists to catch order-of-magnitude regressions like a
dead dispatch or a lost SIMD path, not single-digit percent drift).

Seeding / refreshing the baseline (run on the reference host):

    cd rust && cargo bench --bench gemm_micro
    cp BENCH_gemm.json BENCH_gemm.baseline.json
    git add BENCH_gemm.baseline.json

An empty baseline array (the committed placeholder until a reference
host measures one) makes the gate print the fresh table and exit 0.

Usage:
    python3 tools/bench_gate.py [--fresh rust/BENCH_gemm.json]
        [--baseline rust/BENCH_gemm.baseline.json] [--tolerance 1.6]
"""

import argparse
import json
import sys


def key(rec):
    return (rec["kind"], rec["variant"], rec["m"], rec["n"], rec["k"])


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise SystemExit(f"{path}: expected a JSON array of records")
    return {key(r): r for r in data}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", default="rust/BENCH_gemm.json")
    ap.add_argument("--baseline", default="rust/BENCH_gemm.baseline.json")
    ap.add_argument("--tolerance", type=float, default=1.6,
                    help="max allowed slowdown factor vs baseline (default 1.6)")
    args = ap.parse_args()

    try:
        fresh = load(args.fresh)
    except FileNotFoundError:
        raise SystemExit(f"fresh run not found: {args.fresh} (run `cargo bench --bench gemm_micro` first)")
    try:
        baseline = load(args.baseline)
    except FileNotFoundError:
        print(f"bench_gate: no baseline at {args.baseline}; nothing to gate against.")
        print("Seed it on the reference host (see tools/bench_gate.py docstring).")
        return 0

    if not baseline:
        print(f"bench_gate: baseline {args.baseline} is empty (placeholder); gate skipped.")
        print(f"Fresh run has {len(fresh)} records. Seed the baseline on the reference host:")
        print("    cd rust && cargo bench --bench gemm_micro && cp BENCH_gemm.json BENCH_gemm.baseline.json")
        return 0

    regressions, improved, missing = [], 0, []
    for k, base in sorted(baseline.items()):
        if k not in fresh:
            missing.append(k)
            continue
        f, b = fresh[k], base
        ratio = b["gops"] / f["gops"] if f["gops"] > 0 else float("inf")
        if ratio > args.tolerance:
            regressions.append((k, b["gops"], f["gops"], ratio))
        elif ratio < 1.0:
            improved += 1

    print(f"bench_gate: {len(baseline)} baseline records, {len(fresh)} fresh, "
          f"{improved} improved, {len(regressions)} regressed (tolerance {args.tolerance}x)")
    for k in missing:
        print(f"  WARNING: baseline record {k} missing from fresh run (renamed variant?)")
    new_keys = sorted(set(fresh) - set(baseline))
    for k in new_keys:
        print(f"  note: new record {k} not in baseline yet")
    if regressions:
        print("REGRESSIONS (fresh slower than baseline beyond tolerance):")
        for k, bg, fg, ratio in regressions:
            print(f"  {k}: baseline {bg:.3f} gops -> fresh {fg:.3f} gops ({ratio:.2f}x slower)")
        return 1
    print("bench_gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
