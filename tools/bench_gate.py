#!/usr/bin/env python3
"""Compare fresh bench records against committed baselines.

Three modes, selected by --serve / --overload:

GEMM mode (default). `cargo bench --bench gemm_micro` (run from `rust/`)
writes `rust/BENCH_gemm.json`: a JSON array of records
`{kind, variant, m, n, k, ns_per_iter, gops}`. This gate compares that
fresh run against the committed `rust/BENCH_gemm.baseline.json` keyed by
`(kind, variant, m, n, k)` and fails (exit 1) when any record regresses
by more than `--tolerance` (default 1.6x slower, i.e. fresh gops <
baseline gops / 1.6 — generous, because CI machines are noisy and
shared; the gate exists to catch order-of-magnitude regressions like a
dead dispatch or a lost SIMD path, not single-digit percent drift).

Serve mode (--serve). `cargo run --release -- serve ...` writes
`rust/BENCH_serve.json`: a single JSON object
`{requests, max_batch, replicas, throughput_rps, p50_latency_us,
p95_latency_us, p99_latency_us, ...}`. The gate compares it against the
committed `rust/BENCH_serve.baseline.json` when the
(requests, max_batch, replicas) configuration matches: throughput may
not drop below baseline/tolerance, and p50/p99 latency may not exceed
baseline*tolerance.

Overload mode (--overload). `cargo run --release -- bench-serve ...`
writes `rust/BENCH_overload.json`: one object
`{base_rps, duration_s, max_batch, replicas, ramp, budget_ms, delay_us,
points: [{rps, offered, completed, rejected, expired, shed,
throughput_rps, p50_latency_us, p99_latency_us, max_latency_us}, ...]}`
— the measured saturation curve. When the run configuration matches the
committed `rust/BENCH_overload.baseline.json`, the gate compares
per-point: served throughput may not drop below baseline/tolerance at
any offered rate, accepted-request p99 may not exceed
baseline*tolerance at offered rates up to the baseline's knee (past the
knee the server is intentionally shedding, so p99 there reflects shed
policy rather than service health), and the curve's knee (peak served
throughput) may not sink below baseline_knee/tolerance.

Seeding / refreshing the baselines (run on the reference host):

    cd rust && cargo bench --bench gemm_micro
    cp BENCH_gemm.json BENCH_gemm.baseline.json
    cargo run --release -- serve --requests 64 --replicas 2
    cp BENCH_serve.json BENCH_serve.baseline.json
    cargo run --release -- bench-serve --rps 200 --duration 2 --ramp
    cp BENCH_overload.json BENCH_overload.baseline.json
    git add BENCH_gemm.baseline.json BENCH_serve.baseline.json BENCH_overload.baseline.json

An empty baseline (`[]` for GEMM, `{}` for serve/overload — the
committed placeholders until a reference host measures one) makes the
gate print the fresh record(s) and exit 0.

Usage:
    python3 tools/bench_gate.py [--fresh rust/BENCH_gemm.json]
        [--baseline rust/BENCH_gemm.baseline.json] [--tolerance 1.6]
    python3 tools/bench_gate.py --serve [--fresh rust/BENCH_serve.json]
        [--baseline rust/BENCH_serve.baseline.json] [--tolerance 1.6]
    python3 tools/bench_gate.py --overload [--fresh rust/BENCH_overload.json]
        [--baseline rust/BENCH_overload.baseline.json] [--tolerance 1.6]
"""

import argparse
import json
import sys

SERVE_LATENCY_FIELDS = ("p50_latency_us", "p99_latency_us")


def key(rec):
    return (rec["kind"], rec["variant"], rec["m"], rec["n"], rec["k"])


def load_json(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def load(path):
    data = load_json(path)
    if not isinstance(data, list):
        raise SystemExit(f"{path}: expected a JSON array of records")
    return {key(r): r for r in data}


def gate_gemm(args):
    try:
        fresh = load(args.fresh)
    except FileNotFoundError:
        raise SystemExit(f"fresh run not found: {args.fresh} (run `cargo bench --bench gemm_micro` first)")
    try:
        baseline = load(args.baseline)
    except FileNotFoundError:
        print(f"bench_gate: no baseline at {args.baseline}; nothing to gate against.")
        print("Seed it on the reference host (see tools/bench_gate.py docstring).")
        return 0

    if not baseline:
        print(f"bench_gate: baseline {args.baseline} is empty (placeholder); gate skipped.")
        print(f"Fresh run has {len(fresh)} records. Seed the baseline on the reference host:")
        print("    cd rust && cargo bench --bench gemm_micro && cp BENCH_gemm.json BENCH_gemm.baseline.json")
        return 0

    regressions, improved, missing = [], 0, []
    for k, base in sorted(baseline.items()):
        if k not in fresh:
            missing.append(k)
            continue
        f, b = fresh[k], base
        ratio = b["gops"] / f["gops"] if f["gops"] > 0 else float("inf")
        if ratio > args.tolerance:
            regressions.append((k, b["gops"], f["gops"], ratio))
        elif ratio < 1.0:
            improved += 1

    print(f"bench_gate: {len(baseline)} baseline records, {len(fresh)} fresh, "
          f"{improved} improved, {len(regressions)} regressed (tolerance {args.tolerance}x)")
    for k in missing:
        print(f"  WARNING: baseline record {k} missing from fresh run (renamed variant? "
              f"arch-gated rung like bnn_neon/tnn_neon on a different host?)")
    new_keys = sorted(set(fresh) - set(baseline))
    for k in new_keys:
        print(f"  note: new record {k} not in baseline yet")
    if regressions:
        print("REGRESSIONS (fresh slower than baseline beyond tolerance):")
        for k, bg, fg, ratio in regressions:
            print(f"  {k}: baseline {bg:.3f} gops -> fresh {fg:.3f} gops ({ratio:.2f}x slower)")
        return 1
    print("bench_gate OK")
    return 0


def serve_key(rec):
    return (rec["requests"], rec["max_batch"], rec["replicas"])


def gate_serve(args):
    try:
        fresh = load_json(args.fresh)
    except FileNotFoundError:
        raise SystemExit(f"fresh serve record not found: {args.fresh} "
                         f"(run `cargo run --release -- serve ...` from rust/ first)")
    if not isinstance(fresh, dict) or not fresh:
        raise SystemExit(f"{args.fresh}: expected a non-empty JSON object (a BENCH_serve.json record)")
    try:
        baseline = load_json(args.baseline)
    except FileNotFoundError:
        print(f"bench_gate: no serving baseline at {args.baseline}; nothing to gate against.")
        return 0
    if not isinstance(baseline, dict):
        raise SystemExit(f"{args.baseline}: expected a JSON object")
    if not baseline:
        print(f"bench_gate: serving baseline {args.baseline} is empty (placeholder); gate skipped.")
        print("Seed it on the reference host:")
        print("    cd rust && cargo run --release -- serve --requests 64 --replicas 2 "
              "&& cp BENCH_serve.json BENCH_serve.baseline.json")
        return 0
    if serve_key(baseline) != serve_key(fresh):
        print(f"bench_gate: serve config changed (baseline {serve_key(baseline)} vs fresh "
              f"{serve_key(fresh)}); re-seed the baseline. Gate skipped.")
        return 0

    regressions = []
    bt, ft = baseline["throughput_rps"], fresh["throughput_rps"]
    thr_ratio = bt / ft if ft > 0 else float("inf")
    if thr_ratio > args.tolerance:
        regressions.append(f"throughput_rps: baseline {bt:.1f} -> fresh {ft:.1f} ({thr_ratio:.2f}x slower)")
    for field in SERVE_LATENCY_FIELDS:
        bl, fl = baseline[field], fresh[field]
        ratio = fl / bl if bl > 0 else 0.0
        if ratio > args.tolerance:
            regressions.append(f"{field}: baseline {bl} -> fresh {fl} ({ratio:.2f}x higher)")

    print(f"bench_gate (serve): config {serve_key(fresh)}, throughput {ft:.1f} rps vs baseline {bt:.1f}, "
          f"tolerance {args.tolerance}x")
    if regressions:
        print("SERVING REGRESSIONS:")
        for r in regressions:
            print(f"  {r}")
        return 1
    print("bench_gate OK")
    return 0


def overload_key(rec):
    return (rec["base_rps"], rec["duration_s"], rec["max_batch"], rec["replicas"],
            rec["ramp"], rec["budget_ms"], rec["delay_us"])


def knee(rec):
    """The saturation knee: the point serving peak throughput."""
    return max(rec["points"], key=lambda p: p["throughput_rps"])


def gate_overload(args):
    try:
        fresh = load_json(args.fresh)
    except FileNotFoundError:
        raise SystemExit(f"fresh overload record not found: {args.fresh} "
                         f"(run `cargo run --release -- bench-serve ...` from rust/ first)")
    if not isinstance(fresh, dict) or not fresh.get("points"):
        raise SystemExit(f"{args.fresh}: expected a BENCH_overload.json record with points")
    try:
        baseline = load_json(args.baseline)
    except FileNotFoundError:
        print(f"bench_gate: no overload baseline at {args.baseline}; nothing to gate against.")
        return 0
    if not isinstance(baseline, dict):
        raise SystemExit(f"{args.baseline}: expected a JSON object")
    if not baseline:
        print(f"bench_gate: overload baseline {args.baseline} is empty (placeholder); gate skipped.")
        print("Seed it on the reference host:")
        print("    cd rust && cargo run --release -- bench-serve --rps 200 --duration 2 --ramp "
              "&& cp BENCH_overload.json BENCH_overload.baseline.json")
        return 0
    if overload_key(baseline) != overload_key(fresh):
        print(f"bench_gate: overload config changed (baseline {overload_key(baseline)} vs fresh "
              f"{overload_key(fresh)}); re-seed the baseline. Gate skipped.")
        return 0

    fresh_by_rps = {p["rps"]: p for p in fresh["points"]}
    base_knee, fresh_knee = knee(baseline), knee(fresh)
    regressions = []
    for bp in baseline["points"]:
        fp = fresh_by_rps.get(bp["rps"])
        if fp is None:
            print(f"  WARNING: baseline point rps={bp['rps']} missing from fresh run")
            continue
        bt, ft = bp["throughput_rps"], fp["throughput_rps"]
        ratio = bt / ft if ft > 0 else float("inf")
        if ratio > args.tolerance:
            regressions.append(f"throughput@rps={bp['rps']}: baseline {bt:.1f} -> fresh {ft:.1f} "
                               f"({ratio:.2f}x slower)")
        # p99 is a service-health signal only up to the baseline's knee;
        # past it, latency reflects intentional shedding under overload.
        if bp["rps"] <= base_knee["rps"] and bp["p99_latency_us"] > 0:
            lratio = fp["p99_latency_us"] / bp["p99_latency_us"]
            if lratio > args.tolerance:
                regressions.append(f"p99@rps={bp['rps']}: baseline {bp['p99_latency_us']} µs -> "
                                   f"fresh {fp['p99_latency_us']} µs ({lratio:.2f}x higher)")
    knee_ratio = base_knee["throughput_rps"] / fresh_knee["throughput_rps"] \
        if fresh_knee["throughput_rps"] > 0 else float("inf")
    if knee_ratio > args.tolerance:
        regressions.append(f"knee throughput: baseline {base_knee['throughput_rps']:.1f} -> "
                           f"fresh {fresh_knee['throughput_rps']:.1f} ({knee_ratio:.2f}x slower)")

    print(f"bench_gate (overload): config {overload_key(fresh)}, {len(fresh['points'])} points, "
          f"knee {fresh_knee['throughput_rps']:.1f} rps served vs baseline "
          f"{base_knee['throughput_rps']:.1f}, tolerance {args.tolerance}x")
    if regressions:
        print("OVERLOAD REGRESSIONS:")
        for r in regressions:
            print(f"  {r}")
        return 1
    print("bench_gate OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", default=None)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--serve", action="store_true",
                    help="gate BENCH_serve.json (throughput + p50/p99) instead of BENCH_gemm.json")
    ap.add_argument("--overload", action="store_true",
                    help="gate BENCH_overload.json (saturation curve: per-point throughput, "
                         "pre-knee p99, knee throughput)")
    ap.add_argument("--tolerance", type=float, default=1.6,
                    help="max allowed slowdown factor vs baseline (default 1.6)")
    args = ap.parse_args()

    if args.serve and args.overload:
        raise SystemExit("--serve and --overload are mutually exclusive")
    if args.overload:
        args.fresh = args.fresh or "rust/BENCH_overload.json"
        args.baseline = args.baseline or "rust/BENCH_overload.baseline.json"
        return gate_overload(args)
    if args.serve:
        args.fresh = args.fresh or "rust/BENCH_serve.json"
        args.baseline = args.baseline or "rust/BENCH_serve.baseline.json"
        return gate_serve(args)
    args.fresh = args.fresh or "rust/BENCH_gemm.json"
    args.baseline = args.baseline or "rust/BENCH_gemm.baseline.json"
    return gate_gemm(args)


if __name__ == "__main__":
    sys.exit(main())
