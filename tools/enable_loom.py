#!/usr/bin/env python3
"""Rewrite rust/Cargo.toml so the `loom` feature pulls in the real crate.

The committed manifest declares `loom = []` — an empty feature — because
the default build environment is fully offline and even an *optional*
dependency participates in dependency resolution (the same reason the
`xla` feature ships empty; see the comments in rust/Cargo.toml). The
loom CI lane, which does have network access, runs this script first to
turn the stub into a real optional dependency:

    loom = []          -->  loom = ["dep:loom"]
    (append)                [dependencies]
                            loom = { version = "0.7", optional = true }

The script is idempotent: a second run is a no-op.

Usage: python3 tools/enable_loom.py [path/to/Cargo.toml]
"""

import re
import sys
from pathlib import Path

DEP_BLOCK = '\n[dependencies]\nloom = { version = "0.7", optional = true }\n'


def main() -> int:
    manifest = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent / "rust" / "Cargo.toml"
    text = manifest.read_text()

    # Line-anchored: the [features] comments also spell out the rewritten
    # form, which must not trip the idempotence check.
    if re.search(r'^loom = \["dep:loom"\]', text, flags=re.MULTILINE):
        print(f"{manifest}: loom dependency already enabled")
        return 0

    if "\nloom = []\n" not in text:
        print(f"error: {manifest} has no `loom = []` feature stub to rewrite", file=sys.stderr)
        return 1

    text = text.replace("\nloom = []\n", '\nloom = ["dep:loom"]\n', 1)
    # A real section header sits at the start of a line; the manifest's
    # comments also mention "[dependencies]", which must not count.
    if re.search(r"^\[dependencies\]", text, flags=re.MULTILINE):
        print(f"error: {manifest} already has a [dependencies] section; refusing to append", file=sys.stderr)
        return 1
    text += DEP_BLOCK

    manifest.write_text(text)
    print(f"{manifest}: enabled optional loom dependency")
    return 0


if __name__ == "__main__":
    sys.exit(main())
