//! End-to-end serving driver (the repository's full-stack validation):
//! the batching coordinator serving a ternary CNN under open-loop load,
//! with latency/throughput reporting — plus, when `make artifacts` has
//! been run, the same images through the AOT-compiled JAX/Pallas model
//! via the PJRT runtime, proving all three layers compose.
//!
//! Run: `cargo run --release --example serve_demo`
//! (artifacts optional: `make artifacts` enables the XLA comparison)

use tbgemm::conv::conv2d::ConvKind;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{BatcherConfig, InferenceServer, NativeEngine, ServerConfig};
use tbgemm::nn::builder::{plan_from_config, NetConfig};
use tbgemm::nn::NetPlanConfig;
use tbgemm::runtime::XlaRuntime;
use tbgemm::util::Rng;
use std::time::Duration;

fn main() {
    // ---- replica pool under the coordinator --------------------------
    let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 28, 28, 1, 10);
    println!("starting coordinator over a TNN mobile CNN plan ({} params), 2 replicas", cfg.param_count());
    let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("valid config");
    let server = InferenceServer::with_config(
        Box::new(NativeEngine::new(plan, "tnn-mobile")),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) })
            .with_replicas(2)
            .with_depths(256, 256),
    );

    let requests = 512usize;
    let mut rng = Rng::new(0x5E4E);
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|_| server.submit(Tensor3::random(28, 28, 1, &mut rng)).expect("server up"))
        .collect();
    let mut class_hist = [0usize; 10];
    for rx in pending {
        let resp = rx.recv().expect("response").completed().expect("served, not shed");
        class_hist[resp.predicted] += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!("served {requests} requests in {:.2} s → {:.1} req/s", dt, requests as f64 / dt);
    println!(
        "batches: {} (mean size {:.2}); latency p50={}µs p95={}µs p99={}µs max={}µs",
        m.batches,
        m.mean_batch_size,
        m.p50_latency_us.unwrap_or(0),
        m.p95_latency_us.unwrap_or(0),
        m.p99_latency_us.unwrap_or(0),
        m.max_latency_us
    );
    println!("per-replica requests: {:?}", m.replica_requests);
    println!("prediction histogram: {class_hist:?}");
    assert_eq!(m.requests as usize, requests, "no request lost");

    // ---- AOT/XLA path (all three layers composed) ---------------------
    let artifact = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/model.hlo.txt");
    if !std::path::Path::new(artifact).exists() {
        println!("\n(artifacts/model.hlo.txt not found — run `make artifacts` to exercise the XLA path)");
        return;
    }
    println!("\nloading AOT JAX/Pallas model via PJRT...");
    let rt = XlaRuntime::cpu().expect("PJRT client");
    let model = rt.load_hlo_text(artifact).expect("artifact compiles");
    let mut rng = Rng::new(0x5E4F);
    let batch: Vec<f32> = (0..8 * 12 * 12).map(|_| rng.normalish()).collect();
    let t0 = std::time::Instant::now();
    let iters = 20;
    let mut out = Vec::new();
    for _ in 0..iters {
        out = model.run_f32(&[(batch.clone(), vec![8, 12, 12, 1])]).expect("execute");
    }
    let per_batch_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    let logits = &out[0];
    assert_eq!(logits.len(), 8 * 10);
    assert!(logits.iter().any(|&v| v != 0.0), "XLA model must be live");
    println!(
        "XLA model '{}': batch-8 forward in {:.2} ms ({:.0} img/s); logits[0][..4] = {:?}",
        model.name,
        per_batch_ms,
        8.0 * 1e3 / per_batch_ms,
        &logits[..4]
    );
    println!("three-layer stack verified: Pallas kernel → JAX model → Rust PJRT serving ✓");
}
