//! Reproduce every table of the paper in one run:
//!
//! * Table I  — truth tables (verified exhaustively),
//! * Table II — microkernel instruction counts (measured on the emulated
//!   NEON path),
//! * Table III — the efficiency-ratio matrix, both *predicted* by the
//!   Cortex-A73 cost model and *measured* on this host's native paths
//!   (a reduced grid by default; pass `--full` for all 64 points).
//!
//! Run: `cargo run --release --example table_repro [-- --full]`

use tbgemm::bench::{grid, predicted, ratio};
use tbgemm::costmodel::table2;
use tbgemm::gemm::encode;
use tbgemm::gemm::Kind;

fn main() {
    let full = std::env::args().any(|a| a == "--full");

    // ---- Table I ------------------------------------------------------
    println!("=== Table I: exhaustive truth-table check ===");
    let mut checked = 0;
    for x in [-1i8, 0, 1] {
        for y in [-1i8, 0, 1] {
            let (xp, xm) = encode::encode_ternary(x);
            let (yp, ym) = encode::encode_ternary(y);
            let (zp, zm) = encode::ternary_mul(xp, xm, yp, ym);
            assert_eq!(encode::decode_ternary(zp, zm), x * y);
            checked += 1;
        }
        for y in [-1i8, 1] {
            let (xp, xm) = encode::encode_ternary(x);
            let (up, um) = encode::tbn_mul(xp, xm, encode::encode_binary(y));
            assert_eq!(encode::decode_ternary(up, um), x * y);
            checked += 1;
        }
    }
    println!("all {checked} ternary / ternary-binary products correct ✓\n");

    // ---- Table II -------------------------------------------------------
    println!("=== Table II ===");
    let rows = table2::generate();
    print!("{}", table2::render(&rows));
    println!();

    // ---- Table III (predicted) -----------------------------------------
    println!("=== Table III, predicted by the Cortex-A73 cost model ===");
    let g = grid::paper_grid();
    let m = ratio::ratio_matrix(&predicted::predict_grid(&g));
    print!("{}", ratio::render_ratio_table(&m, "predicted over the full 64-point grid"));
    println!();

    // ---- Table III (measured) -------------------------------------------
    let g = if full { grid::paper_grid() } else { grid::smoke_grid() };
    println!("=== Table III, measured on this host ({} grid points) ===", g.len());
    let times: Vec<_> = Kind::ALL
        .iter()
        .map(|&k| {
            eprintln!("  timing {}...", k.label());
            grid::time_algorithm(k, &g, 3, 5, 0x7AB1E5)
        })
        .collect();
    let m = ratio::ratio_matrix(&times);
    print!("{}", ratio::render_ratio_table(&m, "measured (native paths, x86-64 host)"));
    println!("\nheadline claims:");
    for (desc, ours, paper) in ratio::headline(&m) {
        println!("  {desc:<40} ours {ours:>5.2}  paper {paper:>5.2}");
    }
}
