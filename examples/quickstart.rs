//! Quickstart: the library's public GEMM API in five minutes.
//!
//! Multiplies a ternary activation matrix by pre-packed ternary weights
//! through one `GemmPlan` on all three backends — the scalar oracle, the
//! emulated-NEON path (the paper's exact instruction sequences), and the
//! native fast path — and checks they agree. Then does the same for
//! binary and ternary-binary products.
//!
//! NOTE: this directory sits outside the `rust/` cargo package, so
//! these examples are documentation — they are not compiled by CI. The
//! same backend-sweep flow is compiled and run as `tests/plan_api.rs`
//! and `tests/blocked_gemm.rs`.

use tbgemm::gemm::{Backend, GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Weights};
use tbgemm::util::mat::MatI8;
use tbgemm::util::Rng;

/// Pack `b` once per backend, run `a · b`, and check all backends agree.
fn verify(kind: Kind, a: &MatI8, b: &MatI8) {
    let mut results: Vec<Vec<i32>> = Vec::new();
    // Caller-owned output + scratch, reused across every run.
    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    for backend in Backend::ALL {
        // 1. Plan: pack the weights once, offline (the paper's PackedB).
        let plan = GemmPlan::new(GemmConfig::new(kind, backend), Weights::I8(b))
            .expect("valid weights for this kind");
        // 2. Execute into the caller-owned buffers (typed errors, no
        //    per-call allocation on the native hot path).
        plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("matching LHS");
        results.push(out.as_i32().expect("low-bit kinds produce i32").data.clone());
    }
    assert!(results.windows(2).all(|w| w[0] == w[1]));
    println!(
        "{:?} {}×{} · {}×{}: reference ≡ emulated ≡ native ✓",
        kind, a.rows, a.cols, b.rows, b.cols
    );
}

fn main() {
    let mut rng = Rng::new(2022);
    // A 72×256 ternary activation matrix times a 256×24 ternary weight
    // matrix — one point of the paper's experimental grid.
    let (m, k, n) = (72, 256, 24);

    // TNN: ternary × ternary.
    let a = MatI8::random_ternary(m, k, &mut rng);
    let b = MatI8::random_ternary(k, n, &mut rng);
    verify(Kind::Tnn, &a, &b);

    // TBN: ternary activations × binary weights.
    let bw = MatI8::random_binary(k, n, &mut rng);
    verify(Kind::Tbn, &a, &bw);

    // BNN: binary × binary.
    let ab = MatI8::random_binary(m, k, &mut rng);
    verify(Kind::Bnn, &ab, &bw);

    println!("\nAll three low-bit multiplications verified. Next steps:");
    println!("  repro table2            # regenerate the paper's Table II");
    println!("  repro table3 --smoke    # a quick Table III run");
    println!("  cargo run --release --example cnn_inference");
}
