//! Quickstart: the library's public GEMM API in five minutes.
//!
//! Multiplies a ternary activation matrix by pre-packed ternary weights
//! three ways — the emulated-NEON driver (the paper's exact instruction
//! sequences), the native fast path, and the scalar oracle — and checks
//! they agree. Then does the same for binary and ternary-binary products.
//!
//! Run: `cargo run --release --example quickstart`

use tbgemm::gemm::driver::{GemmDriver, Lhs};
use tbgemm::gemm::native::kernels::{bnn_gemm, tbn_gemm, tnn_gemm};
use tbgemm::gemm::native::{BitRows, PlaneRows};
use tbgemm::gemm::reference::gemm_i8;
use tbgemm::util::mat::{MatI32, MatI8};
use tbgemm::util::Rng;

fn main() {
    let mut rng = Rng::new(2022);
    // A 72×256 ternary activation matrix times a 256×24 ternary weight
    // matrix — one point of the paper's experimental grid.
    let (m, k, n) = (72, 256, 24);

    // --- TNN ---------------------------------------------------------
    let a = MatI8::random_ternary(m, k, &mut rng);
    let b = MatI8::random_ternary(k, n, &mut rng);

    // 1. Pack the weights once, offline (the paper's PackedB).
    let driver = GemmDriver::new_tnn(&b);
    // 2. Multiply with the emulated NEON microkernels.
    let c_emu = driver.multiply_emulated(Lhs::I8(&a)).unwrap_i32();
    // 3. Multiply with the native fast path.
    let ap = PlaneRows::from_ternary(&a);
    let bt = PlaneRows::from_ternary_transposed(&b);
    let mut c_native = MatI32::zeros(m, n);
    tnn_gemm(&ap, &bt, &mut c_native);
    // 4. Check both against the scalar oracle.
    let oracle = gemm_i8(&a, &b);
    assert_eq!(c_emu.data, oracle.data);
    assert_eq!(c_native.data, oracle.data);
    println!("TNN {m}×{k} · {k}×{n}: emulated ≡ native ≡ oracle ✓");

    // --- TBN: ternary activations × binary weights --------------------
    let bw = MatI8::random_binary(k, n, &mut rng);
    let c_emu = GemmDriver::new_tbn(&bw).multiply_emulated(Lhs::I8(&a)).unwrap_i32();
    let mut c_native = MatI32::zeros(m, n);
    tbn_gemm(&ap, &BitRows::from_binary_transposed(&bw), &mut c_native);
    let oracle = gemm_i8(&a, &bw);
    assert_eq!(c_emu.data, oracle.data);
    assert_eq!(c_native.data, oracle.data);
    println!("TBN {m}×{k} · {k}×{n}: emulated ≡ native ≡ oracle ✓");

    // --- BNN: binary × binary -----------------------------------------
    let ab = MatI8::random_binary(m, k, &mut rng);
    let c_emu = GemmDriver::new_bnn(&bw).multiply_emulated(Lhs::I8(&ab)).unwrap_i32();
    let mut c_native = MatI32::zeros(m, n);
    bnn_gemm(&BitRows::from_binary(&ab), &BitRows::from_binary_transposed(&bw), &mut c_native);
    let oracle = gemm_i8(&ab, &bw);
    assert_eq!(c_emu.data, oracle.data);
    assert_eq!(c_native.data, oracle.data);
    println!("BNN {m}×{k} · {k}×{n}: emulated ≡ native ≡ oracle ✓");

    println!("\nAll three low-bit multiplications verified. Next steps:");
    println!("  repro table2            # regenerate the paper's Table II");
    println!("  repro table3 --smoke    # a quick Table III run");
    println!("  cargo run --release --example cnn_inference");
}
