//! End-to-end CNN inference: the workload the paper's introduction
//! motivates — low-bit CNNs classifying images on a mobile-class budget.
//!
//! Builds the same mobile CNN in all three low-bit regimes (TNN, TBN,
//! BNN), runs each over a batch of synthetic images, and reports
//! per-image latency with a per-layer time breakdown — demonstrating the
//! paper's end-to-end claim that the GEMM kernels dominate and that the
//! low-bit orderings carry through whole networks.
//!
//! Run: `cargo run --release --example cnn_inference`

use tbgemm::conv::conv2d::ConvKind;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::nn::builder::{plan_from_config, NetConfig};
use tbgemm::nn::{NetOut, NetPlanConfig};
use tbgemm::util::Rng;
use std::collections::BTreeMap;

fn main() {
    let images = 64usize;
    let (h, w, c, classes) = (28, 28, 1, 10);
    let mut rng = Rng::new(0xA11CE);
    let batch: Vec<Tensor3<f32>> = (0..images).map(|_| Tensor3::random(h, w, c, &mut rng)).collect();

    println!("mobile CNN, {images} synthetic {h}×{w}×{c} images, {classes} classes\n");
    let mut results = Vec::new();
    for kind in [ConvKind::Tnn, ConvKind::Tbn, ConvKind::Bnn] {
        let cfg = NetConfig::mobile_cnn(kind, h, w, c, classes);
        let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("valid config");
        let mut scratch = plan.make_scratch();
        let mut out = NetOut::new();
        // Warm-up + correctness sanity: logits finite, predictions vary.
        let mut preds = std::collections::BTreeSet::new();
        for img in batch.iter().take(8) {
            plan.run(img, &mut out, &mut scratch).expect("plan-shaped image");
            preds.insert(out.predicted());
        }
        assert!(!preds.is_empty());

        let t0 = std::time::Instant::now();
        let mut layer_time: BTreeMap<&'static str, f64> = BTreeMap::new();
        let mut timings = Vec::new();
        for img in &batch {
            plan.run_timed(img, &mut out, &mut scratch, &mut timings).expect("plan-shaped image");
            for t in &timings {
                *layer_time.entry(t.name).or_insert(0.0) += t.seconds;
            }
        }
        let total = t0.elapsed().as_secs_f64();
        let per_image_ms = total * 1e3 / images as f64;
        println!(
            "{:?}: {} params, {:.2} ms/image ({:.0} img/s), {} distinct predictions over 8 probes",
            kind,
            cfg.param_count(),
            per_image_ms,
            images as f64 / total,
            preds.len()
        );
        let conv_frac = layer_time.get("qconv2d").copied().unwrap_or(0.0) / total;
        println!("  per-layer: {layer_time:?}");
        println!("  conv (GEMM) fraction of runtime: {:.0}%", conv_frac * 100.0);
        results.push((kind, per_image_ms));
    }

    println!("\nrelative inference speed (vs TNN):");
    let tnn_ms = results.iter().find(|(k, _)| *k == ConvKind::Tnn).unwrap().1;
    for (kind, ms) in &results {
        println!("  {:?}: {:.2}× ", kind, tnn_ms / ms);
    }
    println!("\nExpected ordering from the paper: BNN fastest, TBN ≈ TNN.");
}
